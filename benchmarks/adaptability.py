"""Paper §IV-C + §I motivation: adaptability to node join / node offline.

Three scenarios mirroring the paper's standard / scale-up / scale-down
deployments, plus the two dynamic events the paper motivates in §I:
a new device added mid-run and a device going offline (partition redeploy).
"""

from __future__ import annotations

from repro.core.cluster import EdgeCluster, make_paper_cluster
from repro.core.deployer import ModelDeployer
from repro.core.monitor import ResourceMonitor
from repro.core.partitioner import ModelPartitioner
from repro.core.pipeline import DistributedInference, run_task_parallel
from repro.core.scheduler import TaskScheduler
from repro.models.graph import mobilenetv2_graph


def run():
    g = mobilenetv2_graph()
    rows = []

    # paper deployment scenarios: 3-node standard, 4-node scale-up, 2-node down
    scenarios = {
        "standard-3node": ("high", "medium", "low"),
        "scaleup-4node": ("high", "high", "medium", "low"),
        "scaledown-2node": ("high", "medium"),
    }
    for name, profs in scenarios.items():
        c = EdgeCluster()
        for i, p in enumerate(profs):
            c.add_node(f"edge-{i}-{p}", p)
        rep = run_task_parallel(c, ModelPartitioner(g),
                                {"standard-3node": 100, "scaleup-4node": 150,
                                 "scaledown-2node": 50}[name], name=name)
        rows.append(dict(config=name, throughput_rps=round(rep.throughput_rps, 3),
                         latency_ms=round(rep.steady_latency_ms, 2),
                         stability=round(rep.stability, 3)))

    # dynamic: node joins mid-run
    c = make_paper_cluster()
    part = ModelPartitioner(g)
    before = run_task_parallel(c, part, 60, name="pre-join")
    c.add_node("edge-3-high", "high")          # new device added
    after = run_task_parallel(c, part, 60, name="post-join")
    rows.append(dict(config="dynamic-node-join",
                     tput_before=round(before.throughput_rps, 3),
                     tput_after=round(after.throughput_rps, 3),
                     gain_pct=round(100 * (after.throughput_rps
                                           / before.throughput_rps - 1), 1)))

    # dynamic: node offline -> partitions redeploy, service continues
    c = make_paper_cluster()
    monitor = ResourceMonitor(c)
    sched = TaskScheduler()
    dep = ModelDeployer(c, monitor, sched)
    plan = ModelPartitioner(g).plan(3)
    placed = dep.deploy_plan(plan)
    victim = placed[2]
    c.remove_node(victim)
    moved = dep.handle_node_offline(victim)
    # run the pipeline on the surviving placement
    d = DistributedInference.__new__(DistributedInference)
    rows.append(dict(config="dynamic-node-offline", victim=victim,
                     partitions_redeployed=len(moved),
                     all_partitions_online=all(
                         c.nodes[nid].online for nid in dep.assignment().values()),
                     redeploy_events=dep.redeploy_events))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
