"""Ablation: NSA scoring weights (paper Eq. 4).

The paper states the 0.2/0.2/0.1/0.5 weights were "experimentally
determined". We ablate them on the heterogeneous task-parallel workload and
report throughput and load-split fairness (ideal split ∝ CPU capability:
50/30/20).

FINDING (recorded in EXPERIMENTS.md): every weighting — including
balance-only — produces identical splits and throughput, in both steady
state and cold-start bursts. The binding mechanisms in Algorithm 1 are the
hard load-threshold skip (line 4) and completion feedback, not the Eq. 4
weights: once a node holds 2 in-flight tasks it is skipped, so placement
rate-matches node capability regardless of scoring. The paper's
"experimentally determined" weights are inert in closed-loop operation.
"""

from __future__ import annotations

import statistics

from repro.core.cluster import make_paper_cluster
from repro.core.partitioner import ModelPartitioner
from repro.core.pipeline import run_task_parallel
from repro.core.scheduler import TaskScheduler
from repro.models.graph import mobilenetv2_graph

WEIGHTS = {
    "paper-0.2/0.2/0.1/0.5": dict(resource=0.2, load=0.2, perf=0.1, balance=0.5),
    "uniform": dict(resource=0.25, load=0.25, perf=0.25, balance=0.25),
    "resource-heavy": dict(resource=0.5, load=0.2, perf=0.1, balance=0.2),
    "perf-heavy": dict(resource=0.1, load=0.2, perf=0.5, balance=0.2),
    "balance-only": dict(resource=0.0, load=0.0, perf=0.0, balance=1.0),
}

IDEAL = {"edge-0-high": 0.5, "edge-1-medium": 0.3, "edge-2-low": 0.2}


def run():
    g = mobilenetv2_graph()
    rows = []
    for name, w in WEIGHTS.items():
        c = make_paper_cluster()
        # monkey-wire the scheduler weights through run_task_parallel
        import repro.core.pipeline as pl
        orig = TaskScheduler.__init__
        def patched(self, weights=None, **kw):
            orig(self, weights=w, **kw)
        TaskScheduler.__init__ = patched
        try:
            rep = run_task_parallel(c, ModelPartitioner(g), 100, name=name)
            # cold-start regime: a one-shot burst where no completions have
            # fed back yet — here the scoring weights actually decide
            c2 = make_paper_cluster()
            burst = run_task_parallel(c2, ModelPartitioner(g), 24,
                                      name=name + "-burst", concurrency=24)
        finally:
            TaskScheduler.__init__ = orig
        counts = {n.node_id: len(n.history) for n in c.online_nodes()}
        total = sum(counts.values())
        split_err = sum(abs(counts.get(k, 0) / total - v)
                        for k, v in IDEAL.items())
        bursts = {n.node_id.split("-")[1]: len(n.history)
                  for n in c2.online_nodes()}
        rows.append(dict(
            config=f"weights-{name}",
            throughput_rps=round(rep.throughput_rps, 3),
            latency_ms=round(rep.steady_latency_ms, 2),
            split={k.split('-')[1]: v for k, v in counts.items()},
            capability_split_error=round(split_err, 3),
            burst_split=bursts,
            burst_p99_ms=round(burst.p99_latency_ms, 1),
        ))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
