"""Paper Table II: resource profiles vs average inference time.

One balanced (3-way-average) MobileNetV2 partition executed on a node of
each profile; paper values are 234.56 / 389.27 / 583.91 ms.
"""

from __future__ import annotations

from repro.core.cost_model import PROFILES, execution_ms
from repro.models.graph import mobilenetv2_graph

PAPER = {"high": 234.56, "medium": 389.27, "low": 583.91}


def run():
    g = mobilenetv2_graph()
    stage_cost = g.total_cost / 3.0
    rows = []
    for name in ("high", "medium", "low"):
        prof = PROFILES[name]
        ms = execution_ms(stage_cost, prof)
        rows.append(dict(
            config=f"profile-{name}", cpu=prof.cpu, mem_mb=prof.mem_mb,
            avg_inference_ms=round(ms, 2), paper_ms=PAPER[name],
            rel_err_pct=round(100 * abs(ms - PAPER[name]) / PAPER[name], 2),
        ))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
