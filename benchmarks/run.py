"""Benchmark driver — one module per paper table/figure.

Prints one CSV block per benchmark: ``name,key=value,...`` rows, plus a
summary line. Exit code reflects reproduction checks (partition sizes must
match the paper exactly; overheads must be in range).
"""

from __future__ import annotations

import sys
import time

from benchmarks import (ablation_weights, adaptability, kernel_bench, overhead,
                        partitioning, scalability, table1_comparative,
                        table2_profiles)

MODULES = [
    ("table1_comparative", table1_comparative),
    ("table2_profiles", table2_profiles),
    ("partitioning", partitioning),
    ("scalability", scalability),
    ("adaptability", adaptability),
    ("overhead", overhead),
    ("ablation_weights", ablation_weights),
    ("kernel_bench", kernel_bench),
]


def main() -> None:
    ok = True
    for name, mod in MODULES:
        t0 = time.perf_counter()
        rows = mod.run()
        dt = time.perf_counter() - t0
        print(f"\n# {name} ({dt:.2f}s)")
        for row in rows:
            cfg = row.pop("config", "")
            print(",".join([f"{name}/{cfg}"] +
                           [f"{k}={v}" for k, v in row.items()]))
        # reproduction gates
        if name == "partitioning":
            for row in rows:
                if "match" in row and not row["match"]:
                    ok = False
                    print(f"!! partition sizes diverge from paper: {row}")
        if name == "overhead":
            oh = rows[0]
            if not (oh["sched_overhead_ms"] == 10.0
                    and oh["monitor_cpu_pct"] <= 1.0):
                ok = False
                print("!! overhead out of paper range")
    print("\nBENCHMARKS", "OK" if ok else "FAILED")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
