"""Pipeline-engine benchmark: 100k-request streams, transfer overlap,
micro-batching, open-loop traffic, multi-tenant serving, and a Table-I
drift guard.

The sections, written to ``BENCH_pipeline.json`` (repo root):

``table1``
    The paper's Table-I configurations (monolithic / AMP4EC / AMP4EC+Cache
    on the 3-node testbed) run through the event engine's default path,
    asserted **bit-for-bit equal** to the legacy loop — the proof that the
    engine refactor did not drift the reproduced model metrics.
``modes``
    Steady-state throughput of the four transfer/batching policies on the
    3-node testbed with the bottleneck stage sending a boundary: the naive
    blocking-send runtime (``serial``), the seed's optimistic accounting
    (``legacy``), DEFER-style overlap, and overlap + 4-way micro-batching.
``openloop``
    An offered-load sweep of Poisson open-loop traffic across the
    closed-loop capacity knee (~1.55 rps on the testbed): goodput, sojourn
    percentiles, deadline hit rate, and peak queue depth per transfer
    model. Shows where overlap + adaptive micro-batching sustains higher
    goodput than the blocking-send runtime once arrivals stop backing off.
``scale``
    A 100k-request stream on the 50-node synthetic cluster (DP-planner
    placement), through the fast parity path, the heap event path with
    overlap + 8-way micro-batching, and the same event path driven by a
    Poisson open-loop arrival process. Asserts the single-digit-second
    wall-time budget and reports simulated-requests-per-wall-second — the
    engine's figure of merit.
``batchcurve``
    The batch-aware planner vs the k=1 planner on a micro-batched
    open-loop stream. A graph with a large-activation front half and a
    fast-but-memory-tight node makes the two objectives disagree: the
    k=1 planner parks the heavy stage on the fast node (best per-item
    time), the batch-aware planner sees the k-scaled working set cross
    that node's memory at the operating micro-batch and routes around
    the thrash knee. Both plans run the identical overloaded open-loop
    stream; the batch-aware plan must win on predicted bottleneck *and*
    simulated goodput (asserted in-bench, pinned exactly). A final row
    prints the committed kernel-calibration artifact's predicted
    testbed bottleneck next to the analytic model's.
``eventspersec``
    The fast-event-core headline: 16 placement-disjoint tenants × 50
    nodes through (a) the heap oracle, (b) the time-wheel core with
    sharding off — asserted **bit-for-bit equal** to the oracle, same
    dispatched event count — and (c) the time-wheel core with automatic
    tenant sharding. Reports events-per-wall-second per row and asserts
    the sharded core's ≥10× events/sec speedup over the heap oracle
    in-bench (the ISSUE-7 acceptance bar).
``dagsweep``
    Operator-DAG dataflow: an MoE-style branched plan (trunk → two
    asymmetric expert arms → join → tail) with a trunk early-exit head
    draining half the requests, reported with per-exit-head goodput, and
    a two-model cascade (cheap branched model escalating its exit misses
    into a MobileNetV2 tenant) against serving every request on the
    expensive model alone — the cascade must win on end-to-end goodput
    (asserted in-bench, committed numbers pinned exactly).
``multitenant``
    The tenancy layer at scale and under arbitration. (a) 3 tenants ×
    20 nodes × 10k open-loop requests each through one shared event heap
    (single-digit-second wall budget; aggregate + per-tenant goodput).
    (b) A shared-node throttle on a tight 10-node fleet over a slow
    fabric: cross-tenant arbitration with k-stage partial migrations vs
    per-tenant independent full re-planning — the arbitrated run must
    sustain strictly higher aggregate goodput (the committed numbers pin
    the win).

Run:  PYTHONPATH=src python benchmarks/pipeline_bench.py
"""

from __future__ import annotations

import json
import pathlib
import time
from typing import Optional

import numpy as np

from repro.core.cluster import make_paper_cluster, make_synthetic_cluster
from repro.core.cost_model import execution_ms_vec, working_set_bytes
from repro.core.engine import EngineConfig
from repro.core.partitioner import ModelPartitioner
from repro.core.pipeline import DistributedInference, run_monolithic
from repro.core.traffic import PoissonArrivals
from repro.models.graph import mobilenetv2_graph

OUT_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_pipeline.json"

#: 3-node assignment where the bottleneck (0.4-CPU) stage sends a boundary,
#: so blocking vs. overlapped transfers are distinguishable in steady state
BOTTLENECK_SENDS = ["edge-2-low", "edge-0-high", "edge-1-medium"]

TABLE1_REQUESTS = 60
MODE_REQUESTS = 400
SCALE_NODES = 50
SCALE_WALL_BUDGET_S = 10.0


def _columns_equal(a, b) -> bool:
    return all(np.array_equal(getattr(a.columns, f), getattr(b.columns, f))
               for f in ("submit_ms", "finish_ms", "comm_ms", "service_ms",
                         "cache_hits", "stages"))


def table1_rows():
    """Table-I configurations through the engine, with the legacy loop as
    the drift oracle (bit-for-bit assertion per configuration)."""
    g = mobilenetv2_graph()
    rows = []

    mono = run_monolithic(make_paper_cluster(("monolithic",)),
                          ModelPartitioner(g), TABLE1_REQUESTS)
    rows.append(mono.row())

    for name, kw, run_kw in (
            ("amp4ec", {}, {}),
            ("amp4ec+cache", dict(use_cache=True), dict(repeat_rate=0.8))):
        d_legacy = DistributedInference(make_paper_cluster(),
                                        ModelPartitioner(g), **kw)
        rep_legacy = d_legacy.run_legacy(TABLE1_REQUESTS, name=name, **run_kw)
        d_engine = DistributedInference(make_paper_cluster(),
                                        ModelPartitioner(g), **kw)
        rep_engine = d_engine.run(TABLE1_REQUESTS, name=name, **run_kw)
        assert _columns_equal(rep_legacy, rep_engine), (
            f"{name}: engine drifted from the legacy loop")
        row = rep_engine.row()
        row["matches_legacy_loop"] = True
        rows.append(row)
    return rows


def fresh_testbed(g):
    """The 3-node testbed pipeline every steady-state section benchmarks:
    3 partitions, bottleneck stage sending a boundary."""
    return DistributedInference(make_paper_cluster(), ModelPartitioner(g),
                                num_partitions=3,
                                assignment=list(BOTTLENECK_SENDS))


def mode_rows(num_requests: int = MODE_REQUESTS):
    """Steady-state comparison of the transfer/batching policies."""
    g = mobilenetv2_graph()

    def fresh():
        return fresh_testbed(g)

    configs = [
        ("serial-blocking-send", EngineConfig(transfer="serial")),
        ("legacy-accounting", None),
        ("overlap", EngineConfig(transfer="overlap")),
        ("overlap+microbatch4", EngineConfig(transfer="overlap",
                                             micro_batch=4)),
    ]
    rows = []
    tail = {}
    for name, cfg in configs:
        rep = fresh().run(num_requests, name=name, engine=cfg)
        tail[name] = rep.tail_throughput_rps()
        rows.append(dict(
            config=name,
            steady_state_ms=round(1000.0 / tail[name], 3),
            tail_throughput_rps=round(tail[name], 5),
            avg_latency_ms=round(rep.avg_latency_ms, 1),
            comm_overhead_ms=round(rep.avg_comm_ms, 2),
        ))
    assert tail["overlap"] > tail["serial-blocking-send"], \
        "overlapped transfer must beat the blocking-send runtime"
    assert tail["overlap+microbatch4"] > tail["legacy-accounting"], \
        "overlap + micro-batching must beat the legacy loop"

    # analytic micro-batch curve for the bottleneck stage from the
    # vectorized cost model: per-request steady time as k grows (the
    # amortization ceiling the simulated overlap+microbatch rows approach)
    d = fresh()
    bott = max(d.plan.partitions,
               key=lambda p: p.cost
               / d.cluster.nodes[d.placement[p.index]].profile.cpu)
    profile = d.cluster.nodes[d.placement[bott.index]].profile
    ks = np.arange(1, 9)
    ws = np.array([working_set_bytes(d.partitioner.graph, bott.lo, bott.hi,
                                     int(k)) for k in ks])
    curve = execution_ms_vec(bott.cost * ks, profile, ws) / ks
    rows.append(dict(
        config="predicted-bottleneck-microbatch-curve",
        # string keys: the committed baseline round-trips through JSON
        per_request_ms={str(int(k)): round(float(v), 3)
                        for k, v in zip(ks, curve)}))
    return rows


#: open-loop sweep: offered Poisson rates straddling the testbed's
#: closed-loop capacity (~1.55 rps with the bottleneck stage sending)
OPENLOOP_RATES = (1.2, 1.5, 1.8, 2.4)
OPENLOOP_REQUESTS = 400
OPENLOOP_DEADLINE_MS = 2000.0
OPENLOOP_SEED = 11


def openloop_rows(num_requests: int = OPENLOOP_REQUESTS):
    """Offered-load sweep: Poisson open-loop arrivals across the capacity
    knee, per transfer model. Bit-reproducible (seeded arrival process),
    so every field is guarded exactly by ``scripts/check_perf.py``."""
    g = mobilenetv2_graph()
    configs = [
        ("serial", EngineConfig(transfer="serial")),
        ("overlap+amb4", EngineConfig(transfer="overlap", micro_batch=4,
                                      fabric="shared", adaptive_batch=True)),
    ]
    rows = []
    goodput = {}
    for rate in OPENLOOP_RATES:
        for name, cfg in configs:
            d = fresh_testbed(g)
            rep = d.run(num_requests, name=name, engine=cfg,
                        arrivals=PoissonArrivals(rate_rps=rate,
                                                 seed=OPENLOOP_SEED))
            gp = rep.goodput_rps(OPENLOOP_DEADLINE_MS)
            assert gp <= rep.offered_load_rps + 1e-9, \
                "goodput exceeded offered load"
            goodput[(name, rate)] = gp
            rows.append(dict(
                config=f"{name}@{rate}rps",
                offered_rps=round(rep.offered_load_rps, 4),
                goodput_rps=round(gp, 4),
                deadline_hit_pct=round(
                    100.0 * rep.deadline_hit_rate(OPENLOOP_DEADLINE_MS), 2),
                p50_sojourn_ms=round(rep.p50_sojourn_ms, 2),
                p99_sojourn_ms=round(rep.p99_sojourn_ms, 2),
                peak_queue_depth=int(rep.queue_depth[1].max()),
            ))
    # the knee: past capacity, overlap + adaptive micro-batching sustains
    # strictly more deadline-meeting goodput than the blocking-send runtime
    top = OPENLOOP_RATES[-1]
    assert goodput[("overlap+amb4", top)] > goodput[("serial", top)], \
        "overlap+micro-batching must sustain higher goodput past the knee"
    return rows


#: closed-loop in-flight window for the scale section: must cover pipeline
#: depth × micro-batch (9 stages × 8) or batches starve and bubbles form
SCALE_CONCURRENCY = 128

#: offered load of the 50-node open-loop scale row: just under the event
#: path's steady-state completion rate (~7.6 rps), so the stream drains
SCALE_OPENLOOP_RPS = 7.0


def scale_rows(num_requests: int = 100_000, nodes: int = SCALE_NODES,
               budget_s: Optional[float] = SCALE_WALL_BUDGET_S):
    """The 100k × 50-node stream through both engine paths; asserts the
    wall-time budget (``budget_s=None`` disables the assert — the perf
    gate uses its own tolerance band and must report, not crash, on slow
    machines) and reports simulated-requests-per-wall-second."""
    g = mobilenetv2_graph()
    rows = []
    for name, cfg, arrivals in (
            ("fast-path-legacy-semantics", None, None),
            ("event-path-overlap+mb8",
             EngineConfig(transfer="overlap", micro_batch=8), None),
            ("openloop-poisson-overlap+mb8",
             EngineConfig(transfer="overlap", micro_batch=8),
             PoissonArrivals(rate_rps=SCALE_OPENLOOP_RPS,
                             seed=OPENLOOP_SEED))):
        cluster = make_synthetic_cluster(nodes, seed=7)
        d = DistributedInference(cluster, ModelPartitioner(g),
                                 method="planner")
        t0 = time.perf_counter()
        rep = d.run(num_requests, name=name, concurrency=SCALE_CONCURRENCY,
                    engine=cfg, arrivals=arrivals)
        wall_s = time.perf_counter() - t0
        if budget_s is not None and wall_s >= budget_s:
            raise RuntimeError(
                f"{name}: {num_requests} requests took {wall_s:.1f}s "
                f"(> {budget_s:.0f}s budget)")
        rows.append(dict(
            config=name,
            num_requests=num_requests,
            nodes=nodes,
            stages=len(d.plan.partitions),
            wall_s=round(wall_s, 2),
            sim_req_per_wall_s=round(num_requests / wall_s, 0),
            tail_throughput_rps=round(rep.tail_throughput_rps(), 4),
            sim_makespan_s=round(
                float(rep.columns.finish_ms.max()
                      - rep.columns.submit_ms.min()) / 1e3, 1),
        ))
    return rows


# --- batch-aware planning ----------------------------------------------------

#: the operating micro-batch of the batchcurve scenario (and the expected_k
#: the batch-aware planner costs stages at)
BC_K = 8
BC_REQUESTS = 800
BC_RATE_RPS = 75.0           # above the k=1 plan's batched capacity knee
BC_DEADLINE_MS = 800.0
BC_SEED = 17
#: large per-layer activation of the front half: at k=1 it fits the fast
#: node's memory; at BC_K the k-scaled working set crosses it (thrash knee)
BC_HEAVY_ACT = 8 * 1024 * 1024


def batchcurve_graph():
    """Synthetic 12-layer graph whose k=1-optimal and batch-aware-optimal
    plans differ: a compute-heavy, large-activation front half and a
    lighter, small-activation back half."""
    from repro.models.graph import LayerSpec, ModelGraph
    layers = []
    for i in range(6):
        ob = BC_HEAVY_ACT if i < 5 else 64 * 1024
        layers.append(LayerSpec(f"heavy{i}", "Conv2d", 0, 100_000,
                                out_bytes=ob))
    for i in range(6):
        layers.append(LayerSpec(f"light{i}", "Linear", 0, 60_000,
                                out_bytes=64 * 1024))
    return ModelGraph("batchcurve-toy", layers)


def batchcurve_cluster():
    """Three nodes where per-item speed and batched capacity disagree:
    one fast node with memory for the heavy stage at k=1 but not at
    ``BC_K``, and two slower nodes with headroom."""
    from repro.core.cluster import EdgeCluster
    from repro.core.cost_model import NodeProfile
    c = EdgeCluster()
    c.add_node("turbo-lowmem", NodeProfile(cpu=1.0, mem_mb=24.0,
                                           net_bw_mbps=8000.0))
    c.add_node("std-0", NodeProfile(cpu=0.55, mem_mb=1024.0,
                                    net_bw_mbps=8000.0))
    c.add_node("std-1", NodeProfile(cpu=0.55, mem_mb=1024.0,
                                    net_bw_mbps=8000.0))
    return c


def batchcurve_rows(num_requests: int = BC_REQUESTS):
    """k=1 planner vs batch-aware planner on the identical micro-batched
    open-loop stream, plus the calibrated-artifact comparison row. Fully
    deterministic (analytic cost model + seeded arrivals + committed
    artifact), so every field is guarded exactly."""
    from repro.core.cost_model import CALIBRATION_ARTIFACT, BatchCostModel
    from repro.core.planner import bottleneck_ms

    g = batchcurve_graph()
    rows = []
    result = {}
    for label, ek in (("planner-k1", 1), ("planner-batchaware", BC_K)):
        cluster = batchcurve_cluster()
        d = DistributedInference(cluster, ModelPartitioner(g),
                                 method="planner", expected_k=ek)
        cuts = [p.lo for p in d.plan.partitions] + [len(g.layers)]
        pred_k1 = bottleneck_ms(g, d.plan.partitions, d.placement, cluster)
        pred_kb = bottleneck_ms(g, d.plan.partitions, d.placement, cluster,
                                expected_k=BC_K)
        rep = d.run(num_requests, name=label,
                    engine=EngineConfig(transfer="overlap",
                                        micro_batch=BC_K,
                                        adaptive_batch=True),
                    arrivals=PoissonArrivals(rate_rps=BC_RATE_RPS,
                                             seed=BC_SEED))
        gp = rep.goodput_rps(BC_DEADLINE_MS)
        result[label] = dict(cuts=cuts, placement=dict(d.placement),
                             pred_kb=pred_kb, goodput=gp)
        rows.append(dict(
            config=label,
            expected_k=ek,
            cuts=cuts,
            assignment=[d.placement[i] for i in range(len(cuts) - 1)],
            predicted_bottleneck_k1_ms=round(pred_k1, 3),
            predicted_bottleneck_k8_ms=round(pred_kb, 3),
            goodput_rps=round(gp, 4),
            p50_sojourn_ms=round(rep.p50_sojourn_ms, 2),
            p99_sojourn_ms=round(rep.p99_sojourn_ms, 2),
            peak_queue_depth=int(rep.queue_depth[1].max()),
        ))
    a, b = result["planner-k1"], result["planner-batchaware"]
    assert a["cuts"] != b["cuts"] or a["placement"] != b["placement"], \
        "the batch-aware planner must pick a different plan at k=8"
    assert b["pred_kb"] < a["pred_kb"], (
        "batch-aware plan must have the lower predicted bottleneck at the "
        f"operating micro-batch: {b['pred_kb']:.2f} vs {a['pred_kb']:.2f}")
    assert b["goodput"] > a["goodput"], (
        "batch-aware plan must win on simulated open-loop goodput: "
        f"{b['goodput']:.2f} vs {a['goodput']:.2f}")

    # the committed kernel-calibration artifact vs the analytic fallback on
    # the paper testbed (deterministic: reads only the in-repo JSON)
    artifact = OUT_PATH.parent / CALIBRATION_ARTIFACT
    model = BatchCostModel.from_artifact(artifact)
    gm = mobilenetv2_graph()
    cluster = make_paper_cluster()
    d = DistributedInference(cluster, ModelPartitioner(gm), method="planner")
    kw = dict(batch=1, calibration=d.partitioner.calibration,
              speedup=d.deployer.speedup)
    rows.append(dict(
        config="calibrated-artifact-testbed",
        source=model.source,
        analytic_bottleneck_k4_ms=round(bottleneck_ms(
            gm, d.plan.partitions, d.placement, cluster,
            expected_k=4, **kw), 3),
        calibrated_bottleneck_k4_ms=round(bottleneck_ms(
            gm, d.plan.partitions, d.placement, cluster,
            expected_k=4, batch_model=model, **kw), 3),
    ))
    return rows


# --- fault storm --------------------------------------------------------------

#: the fault-storm scenario: one MobileNetV2 tenant on an 8-node fleet,
#: Poisson open-loop traffic with an SLO deadline, under transient node
#: crash/restart + transfer loss + execution faults + heavy-tailed
#: stragglers (one seeded draw sequence per policy, so every row is
#: bit-reproducible and guarded exactly by ``scripts/check_perf.py``)
FS_NODES = 8
FS_CRASH_NODES = 4           # only the first half of the fleet crash-cycles
FS_REQUESTS = 600
FS_RATE_RPS = 2.0
FS_DEADLINE_MS = 2500.0
FS_SEED = 23
FS_HAZARDS = dict(seed=FS_SEED, crash_mtbf_ms=30_000.0,
                  crash_mttr_ms=1500.0, loss_rate=0.01,
                  exec_fail_rate=0.01, straggler_rate=0.05,
                  straggler_shape=2.5, straggler_scale=2.0)
#: the retry policy shared by the non-naive rungs: backoff_base covers a
#: full crash_mttr window within two attempts, timeouts cut stragglers
#: loose at 3x the predicted stage time
FS_RETRY = dict(max_attempts=6, backoff_base_ms=250.0, timeout_slack=3.0)


def faultstorm_rows(num_requests: int = FS_REQUESTS):
    """Recovery-policy ladder under the identical seeded fault storm:
    naive fail-on-first-error, retry+timeout+backoff, and the full
    policy (retries + hedged duplicates + deadline-aware shedding). The
    full policy must beat naive on deadline-meeting goodput AND on p99
    sojourn over completed requests (asserted here, so the committed
    numbers are load-bearing)."""
    from repro.core.faults import FaultConfig
    from repro.core.tenancy import TenantRegistry, TenantTraffic

    # naive-fail is the ISSUE's fail-and-replan baseline: one attempt per
    # request, and every transient crash tears down and re-places the
    # partition plan (repair_on_crash=True); the resilient rungs instead
    # ride out a crash_mttr window with retry+backoff
    policies = [
        ("naive-fail", dict(max_attempts=1, repair_on_crash=True)),
        ("retry-backoff", dict(FS_RETRY)),
        ("resilient-hedge+shed", dict(FS_RETRY, hedge=True, shed=True)),
    ]
    g = mobilenetv2_graph()
    rows = []
    stats = {}
    for label, policy in policies:
        cluster = make_synthetic_cluster(FS_NODES, seed=3)
        fc = FaultConfig(crash_nodes=tuple(list(cluster.nodes)[:FS_CRASH_NODES]),
                         **FS_HAZARDS, **policy)
        reg = TenantRegistry(cluster)
        reg.add("storm", ModelPartitioner(g),
                traffic=TenantTraffic(
                    num_requests=num_requests, seed=FS_SEED,
                    concurrency=32, deadline_ms=FS_DEADLINE_MS,
                    arrivals=PoissonArrivals(rate_rps=FS_RATE_RPS,
                                             seed=FS_SEED)),
                method="planner")
        rep = reg.run(name=label,
                      engine=EngineConfig(transfer="overlap", micro_batch=4,
                                          adaptive_batch=True,
                                          faults=fc))["storm"]
        fs = rep.fault_stats
        done = rep.columns.status == 0
        p99_done = float(np.percentile(rep.columns.sojourn_ms[done], 99))
        gp = rep.goodput_rps(FS_DEADLINE_MS)
        stats[label] = (gp, p99_done)
        rows.append(dict(
            config=label,
            num_requests=num_requests,
            done=fs["done"], shed=fs["shed"], failed=fs["failed"],
            availability=round(fs["availability"], 4),
            retries=fs["retries_total"], hedges=fs["hedges_total"],
            crashes=fs["crashes"], restarts=fs["restarts"],
            goodput_rps=round(gp, 4),
            p99_done_sojourn_ms=round(p99_done, 2),
        ))
    naive, full = stats["naive-fail"], stats["resilient-hedge+shed"]
    assert full[0] > naive[0], (
        "the full recovery policy must beat naive fail-on-error on "
        f"deadline-meeting goodput: {full[0]:.3f} vs {naive[0]:.3f}")
    assert full[1] < naive[1], (
        "the full recovery policy must beat naive fail-on-error on p99 "
        f"sojourn over completed requests: {full[1]:.1f} vs {naive[1]:.1f}")
    return rows


# --- fast event core ---------------------------------------------------------

#: the events/sec scenario: placement-disjoint tenants on 3-node slices of
#: the 50-node cluster, lightly loaded (2000 ms arrival gap > the ~1.5 s
#: per-request chain), so the uncontended fused path and tenant sharding
#: both engage — the operating point the fast core is built for
EV_TENANTS = 16
EV_NODES = 50
EV_REQUESTS = 10_000         # total, split across the tenants
EV_RATE_RPS = 0.5            # per tenant
EV_CONCURRENCY = 8
#: the fast core's acceptance bar: sharded events/sec vs the heap oracle
EV_SPEEDUP_FLOOR = 10.0

#: the contended events/sec variant: the same 16 disjoint 3-node slices,
#: but driven hot (Poisson arrivals, concurrency 32, adaptive micro-batch
#: 8), so back-to-back same-node micro-batches dominate the stream — the
#: operating point contended-chain fusion plus forked sharding targets
EVC_RATE_RPS = 8.0
EVC_CONCURRENCY = 32
EVC_WORKERS = 4
#: the adaptive events/sec variant: every tenant carries an
#: AdaptationController scoped to its own disjoint ``nodes=`` closure, so
#: the sharder free-runs the groups between 1 Hz epoch barriers and the
#: coordinator polls each closure locally instead of the whole fleet.
#: 32 tenants: the interleaved tick cost scales with streams × fleet
#: size, the closure tick with streams × closure size, so the fleet is
#: sized where that gap (not noise) dominates the measured ratio
EVA_TENANTS = 32
EVA_NODES_PER = 3
EVA_REQUESTS = 9_600
EVA_RATE_RPS = 12.0
EVA_CONCURRENCY = 24
#: both sharded variants must clear this × the *interleaved* fast core
EV_SHARD_FLOOR = 2.0
#: the forked lane re-pays fork()+pickle per shard, so it gets a laxer
#: floor — its committed metric is the slim column-pipe payload size
EV_FORK_FLOOR = 1.2


def _ev_registry():
    """A fresh registry of ``EV_TENANTS`` MobileNetV2 tenants, each pinned
    to its own disjoint 3-node slice (explicit assignment, so every core
    sees the identical placement and the sharder finds the groups)."""
    from repro.core.tenancy import TenantRegistry, TenantTraffic
    from repro.core.traffic import DeterministicArrivals

    cluster = make_synthetic_cluster(EV_NODES, seed=7)
    nids = list(cluster.nodes)
    reg = TenantRegistry(cluster)
    g = mobilenetv2_graph()
    per_tenant = EV_REQUESTS // EV_TENANTS
    for i in range(EV_TENANTS):
        reg.add(f"t{i}", ModelPartitioner(g),
                traffic=TenantTraffic(
                    num_requests=per_tenant, seed=i,
                    concurrency=EV_CONCURRENCY,
                    arrivals=DeterministicArrivals.at_rate(EV_RATE_RPS)),
                num_partitions=3,
                assignment=nids[3 * i:3 * i + 3])
    return reg


def _evc_registry():
    """The contended variant of :func:`_ev_registry`: identical disjoint
    slices, open-loop Poisson storms well past each slice's capacity."""
    from repro.core.tenancy import TenantRegistry, TenantTraffic

    cluster = make_synthetic_cluster(EV_NODES, seed=7)
    nids = list(cluster.nodes)
    reg = TenantRegistry(cluster)
    g = mobilenetv2_graph()
    per_tenant = EV_REQUESTS // EV_TENANTS
    for i in range(EV_TENANTS):
        reg.add(f"t{i}", ModelPartitioner(g),
                traffic=TenantTraffic(
                    num_requests=per_tenant, seed=i,
                    concurrency=EVC_CONCURRENCY,
                    arrivals=PoissonArrivals(rate_rps=EVC_RATE_RPS,
                                             seed=100 + i)),
                num_partitions=3,
                assignment=nids[3 * i:3 * i + 3])
    return reg


def _eva_registry():
    """The adaptive variant: per-tenant AdaptationControllers, each scoped
    to its own disjoint 3-node ``nodes=`` closure (planner-placed, so the
    sharder derives the groups from the declared migration closures)."""
    from repro.core.tenancy import TenantRegistry, TenantTraffic

    cluster = make_synthetic_cluster(EVA_TENANTS * EVA_NODES_PER, seed=7)
    nids = list(cluster.nodes)
    reg = TenantRegistry(cluster)
    g = mobilenetv2_graph()
    per_tenant = EVA_REQUESTS // EVA_TENANTS
    for i in range(EVA_TENANTS):
        reg.add(f"t{i}", ModelPartitioner(g),
                traffic=TenantTraffic(
                    num_requests=per_tenant, seed=i,
                    concurrency=EVA_CONCURRENCY,
                    arrivals=PoissonArrivals(rate_rps=EVA_RATE_RPS,
                                             seed=100 + i)),
                num_partitions=3, method="planner", adaptive=True,
                nodes=nids[EVA_NODES_PER * i:EVA_NODES_PER * (i + 1)])
    return reg


def eventspersec_rows():
    """Heap oracle vs the time-wheel core (sharding off, then auto) on the
    identical 16-tenant scenario. The unsharded fast row must reproduce
    the oracle bit-for-bit with the same dispatched event count; the
    sharded row must clear ``EV_SPEEDUP_FLOOR``× the oracle's events/sec
    (both asserted here, so the committed numbers are load-bearing).

    Then two sharded-vs-interleaved pairs on the operating points tenant
    sharding targets: the contended storm (contended-chain fusion hot)
    and the adaptive fleet (per-closure controllers free-running between
    epoch barriers). Each sharded row must dispatch the identical event
    count, reproduce the interleaved columns and adaptation logs
    bit-for-bit, and clear ``EV_SHARD_FLOOR``× the interleaved fast
    core's events/sec. A final forked row re-runs the contended storm
    across worker processes, metering the slim column-pipe payload
    (``pipe_bytes``) under the laxer ``EV_FORK_FLOOR``."""
    from repro.core import engine as eng_mod
    from repro.core import fastcore

    rows = []
    runs = {}
    for label, core, shards in (("heap-oracle", "heap", "none"),
                                ("fastcore", "fast", "none"),
                                ("fastcore+shards", "fast", "auto")):
        reg = _ev_registry()
        cfg = EngineConfig(core=core, shards=shards)
        t0 = time.perf_counter()
        result = reg.run(name=label, engine=cfg)
        wall_s = time.perf_counter() - t0
        nev = (eng_mod.LAST_EVENT_COUNT if core == "heap"
               else fastcore.LAST_EVENT_COUNT)
        runs[label] = (result, nev, nev / wall_s)
        rows.append(dict(
            config=label,
            tenants=EV_TENANTS,
            nodes=EV_NODES,
            num_requests=EV_REQUESTS,
            events=nev,
            wall_s=round(wall_s, 2),
            events_per_sec=round(nev / wall_s, 0),
        ))

    oracle, fast, sharded = (runs[k] for k in
                             ("heap-oracle", "fastcore", "fastcore+shards"))
    for name, rep in oracle[0].reports.items():
        assert fast[0].reports[name].columns.bitwise_equal(rep.columns), (
            f"fast core drifted from the heap oracle on tenant {name!r}")
        assert sharded[0].reports[name].columns.bitwise_equal(rep.columns), (
            f"sharded fast core drifted from the oracle on tenant {name!r}")
    assert fast[1] == oracle[1], (
        f"unsharded fast core dispatched {fast[1]} events, "
        f"oracle {oracle[1]} — the cores disagree on the event stream")
    rows[1]["matches_heap_oracle"] = True

    speedup = sharded[2] / oracle[2]
    assert speedup >= EV_SPEEDUP_FLOOR, (
        f"sharded fast core managed only {speedup:.1f}× the oracle's "
        f"events/sec (floor {EV_SPEEDUP_FLOOR:.0f}×)")
    rows[2]["matches_oracle_columns"] = True
    rows[2]["speedup_vs_heap"] = round(speedup, 1)

    def _measure(label, mk, shards, workers=0, tenants=EV_TENANTS,
                 total=EV_REQUESTS):
        reg = mk()
        cfg = EngineConfig(core="fast", shards=shards, micro_batch=8,
                           adaptive_batch=True, shard_workers=workers)
        t0 = time.perf_counter()
        result = reg.run(name=label, engine=cfg)
        wall_s = time.perf_counter() - t0
        nev = fastcore.LAST_EVENT_COUNT
        rows.append(dict(
            config=label,
            tenants=tenants,
            num_requests=total,
            events=nev,
            wall_s=round(wall_s, 2),
            events_per_sec=round(nev / wall_s, 0),
        ))
        return result, nev, nev / wall_s

    def _assert_pair(tag, base, shard, floor):
        assert shard[1] == base[1], (
            f"{tag}: sharded fast core dispatched {shard[1]} events, "
            f"interleaved {base[1]} — the shard merge lost or invented "
            f"events")
        for name, rep in base[0].reports.items():
            srep = shard[0].reports[name]
            assert srep.columns.bitwise_equal(rep.columns), (
                f"{tag}: sharded run drifted from interleaved on tenant "
                f"{name!r}")
            assert srep.adaptation == rep.adaptation, (
                f"{tag}: sharded run drifted on tenant {name!r}'s "
                f"adaptation log")
        sp = shard[2] / base[2]
        assert sp >= floor, (
            f"{tag}: sharded fast core managed only {sp:.2f}× the "
            f"interleaved core's events/sec (floor {floor:.1f}×)")
        rows[-1]["matches_interleaved"] = True
        rows[-1]["speedup_vs_interleaved"] = round(sp, 1)

    contended_base = None
    for tag, mk, tenants, total in (
            ("contended", _evc_registry, EV_TENANTS, EV_REQUESTS),
            ("adaptive", _eva_registry, EVA_TENANTS, EVA_REQUESTS)):
        base = _measure(f"fastcore-{tag}", mk, "none",
                        tenants=tenants, total=total)
        shard = _measure(f"fastcore-{tag}+shards", mk, "auto",
                         tenants=tenants, total=total)
        _assert_pair(tag, base, shard, EV_SHARD_FLOOR)
        if tag == "contended":
            contended_base = base

    # the forked lane on the contended storm: shards round-robin across
    # worker processes and ship the slim per-group column state back over
    # the pipe — metered here so pickle-payload regressions show up in
    # the committed row
    forked = _measure("fastcore-contended+shards-forked", _evc_registry,
                      "auto", workers=EVC_WORKERS)
    _assert_pair("contended-forked", contended_base, forked, EV_FORK_FLOOR)
    assert fastcore.LAST_SHARD_PIPE_BYTES > 0, (
        "forked sharded run shipped no column state over the pipe — "
        "fork mode silently fell back to in-process")
    rows[-1]["pipe_bytes"] = fastcore.LAST_SHARD_PIPE_BYTES
    return rows


# --- operator-DAG dataflow ----------------------------------------------------

#: the dagsweep scenario: an MoE-style branched plan (trunk -> 2 asymmetric
#: expert arms -> join -> tail) whose trunk head early-exits half the
#: requests, and a two-model cascade where the cheap branched model
#: escalates its misses into a MobileNetV2 tenant
DAG_REQUESTS = 400
DAG_SEED = 29
DAG_DEADLINE_MS = 2000.0
DAG_EXIT_PROB = 0.5
CASCADE_REQUESTS = 300
CASCADE_DEADLINE_MS = 2000.0


def dagsweep_rows(num_requests: int = DAG_REQUESTS):
    """Branched early-exit plans through the DAG planner + engine
    (per-exit-head goodput reported per row), then the model cascade vs
    serving every request on the expensive model alone — the cascade
    must win on end-to-end goodput (asserted here, so the committed
    numbers are load-bearing). Fully deterministic: closed-loop streams
    and seeded per-request exit draws."""
    from repro.core.tenancy import TenantRegistry, TenantTraffic
    from repro.models.graph import branched_graph

    rows = []
    g = branched_graph(exit_prob=DAG_EXIT_PROB)
    for label, cfg in (
            ("dag-branched-exit", None),
            ("dag-branched-exit-overlap+mb4",
             EngineConfig(transfer="overlap", micro_batch=4))):
        d = DistributedInference(make_paper_cluster(), ModelPartitioner(g),
                                 method="planner")
        rep = d.run(num_requests, name=label, seed=DAG_SEED, concurrency=8,
                    engine=cfg)
        row = rep.row()
        row["goodput_by_exit"] = {
            ("tail" if h < 0 else str(h)): round(v, 4)
            for h, v in sorted(rep.goodput_by_exit(DAG_DEADLINE_MS).items())}
        rows.append(row)

    # cascade vs expensive-only: identical request count; the cascade's
    # end-to-end latency of an escalated request spans cheap submit ->
    # big finish (escalations enter the big tenant in cheap-finish order,
    # so the positional match below is exact)
    gm = mobilenetv2_graph()
    reg = TenantRegistry(make_paper_cluster())
    reg.add("cheap", ModelPartitioner(branched_graph(exit_prob=DAG_EXIT_PROB)),
            traffic=TenantTraffic(num_requests=CASCADE_REQUESTS, seed=DAG_SEED,
                                  concurrency=8, escalate_to="big"),
            num_partitions=3, method="planner")
    reg.add("big", ModelPartitioner(gm),
            traffic=TenantTraffic(num_requests=CASCADE_REQUESTS, seed=DAG_SEED,
                                  concurrency=8),
            num_partitions=3, method="planner")
    res = reg.run(name="cascade")
    cheap, big = res.reports["cheap"], res.reports["big"]
    miss = cheap.columns.exit_head == -1
    order = np.argsort(cheap.columns.finish_ms[miss], kind="stable")
    start = np.concatenate([cheap.columns.submit_ms[~miss],
                            cheap.columns.submit_ms[miss][order]])
    finish = np.concatenate([cheap.columns.finish_ms[~miss],
                             big.columns.finish_ms])
    span_s = (float(finish.max()) - float(start.min())) / 1e3
    met = int(((finish - start) <= CASCADE_DEADLINE_MS).sum())
    cascade_goodput = met / span_s
    rows.append(dict(
        config="cascade-cheap->big",
        num_requests=CASCADE_REQUESTS,
        escalated=int(miss.sum()),
        exit_rate=round(float((~miss).mean()), 4),
        goodput_rps=round(cascade_goodput, 4),
        p99_end_to_end_ms=round(float(np.percentile(finish - start, 99)), 2),
    ))

    d = DistributedInference(make_paper_cluster(), ModelPartitioner(gm),
                             method="planner")
    rep = d.run(CASCADE_REQUESTS, name="big-only-baseline", seed=DAG_SEED,
                concurrency=8)
    baseline_goodput = rep.goodput_rps(CASCADE_DEADLINE_MS)
    row = rep.row()
    row["goodput_rps"] = round(baseline_goodput, 4)
    rows.append(row)
    assert cascade_goodput > baseline_goodput, (
        "the cascade must beat serving everything on the expensive model: "
        f"{cascade_goodput:.3f} vs {baseline_goodput:.3f} rps")
    return rows


# --- multi-tenant serving -----------------------------------------------------

#: the tenancy scale row: 3 tenants × 20 nodes × 10k open-loop requests
#: each, one shared event heap (the ISSUE-5 acceptance configuration)
MT_TENANTS = ("vision-a", "vision-b", "vision-c")
MT_NODES = 20
MT_REQUESTS = 10_000
MT_RATE_RPS = 0.8            # per tenant: aggregate just under capacity
MT_DEADLINE_MS = 3000.0
MT_WALL_BUDGET_S = 10.0

#: arbitration comparison: a tight fleet over a slow fabric, where a
#: shared-node throttle makes every controller want to move at once and
#: full-replan transfers are expensive enough to fail the economics gate
ARB_NODES = 10
ARB_CLUSTER_SEED = 5
ARB_REQUESTS = 2_000
ARB_RATE_RPS = 0.6
ARB_NET_BW_MBPS = 30.0
ARB_DEADLINE_MS = 1500.0
ARB_THROTTLE_AT_MS = 30_000.0
ARB_PARTIAL_K = 2


def _mt_registry(nodes: int, cluster_seed: int, num_requests: int,
                 rate_rps: float, deadline_ms: float,
                 adaptive: bool = False, partial_k: int = 0,
                 net_bw_mbps: Optional[float] = None):
    """A fresh registry of three MobileNetV2 tenants with Poisson
    open-loop traffic on a synthetic cluster (jointly planner-deployed:
    each tenant plans around the budgets earlier tenants committed)."""
    from repro.core.adaptation import AdaptationConfig
    from repro.core.tenancy import TenantRegistry, TenantTraffic

    cluster = make_synthetic_cluster(nodes, seed=cluster_seed)
    if net_bw_mbps is not None:
        for nid in cluster.nodes:
            cluster.set_profile(nid, net_bw_mbps=net_bw_mbps)
    reg = TenantRegistry(cluster)
    g = mobilenetv2_graph()
    for i, name in enumerate(MT_TENANTS):
        kw = dict(method="planner")
        if adaptive:
            kw.update(adaptation=AdaptationConfig(
                partial_migration_k=partial_k))
        reg.add(name, ModelPartitioner(g),
                traffic=TenantTraffic(
                    num_requests=num_requests,
                    arrivals=PoissonArrivals(rate_rps=rate_rps, seed=i),
                    concurrency=32, seed=i, deadline_ms=deadline_ms),
                **kw)
    return reg


def _shared_throttle(reg):
    """Throttle the node serving the most tenants to the paper's
    low-resource floor — the drift that makes every tenant's controller
    want to migrate at the same control tick."""
    from repro.core.adaptation import cpu_throttle
    shared = {}
    for t in reg.tenants.values():
        for nid in set(t.placement.values()):
            shared[nid] = shared.get(nid, 0) + 1
    victim = max(sorted(shared), key=lambda nid: shared[nid])
    return [cpu_throttle(ARB_THROTTLE_AT_MS, victim, cpu=0.1, mem_mb=256.0)]


def multitenant_rows(num_requests: int = MT_REQUESTS,
                     budget_s: Optional[float] = MT_WALL_BUDGET_S):
    """The tenancy sections: the 3×20×10k shared-heap scale row, then the
    arbitration-vs-independent comparison under a shared-node throttle."""
    rows = []

    # (a) scale: one shared event heap interleaving 3 tenants' streams
    reg = _mt_registry(MT_NODES, 7, num_requests, MT_RATE_RPS,
                       MT_DEADLINE_MS)
    t0 = time.perf_counter()
    rep = reg.run(name="mt-3x20-openloop",
                  engine=EngineConfig(transfer="overlap", micro_batch=4))
    wall_s = time.perf_counter() - t0
    if budget_s is not None and wall_s >= budget_s:
        raise RuntimeError(
            f"multitenant scale: {rep.num_requests} requests took "
            f"{wall_s:.1f}s (> {budget_s:.0f}s budget)")
    row = rep.row()
    row.update(nodes=MT_NODES, wall_s=round(wall_s, 2),
               sim_req_per_wall_s=round(rep.num_requests / wall_s, 0))
    rows.append(row)

    # (b) arbitration: cross-tenant best-net-gain + partial migrations
    # vs per-tenant independent full re-planning, identical drift
    goodput = {}
    for label, arbitration, partial_k in (
            ("mt-arbitrated+partial", True, ARB_PARTIAL_K),
            ("mt-independent-replan", False, 0)):
        reg = _mt_registry(ARB_NODES, ARB_CLUSTER_SEED, ARB_REQUESTS,
                           ARB_RATE_RPS, ARB_DEADLINE_MS, adaptive=True,
                           partial_k=partial_k,
                           net_bw_mbps=ARB_NET_BW_MBPS)
        rep = reg.run(name=label, scenario=_shared_throttle(reg),
                      engine=EngineConfig(transfer="overlap",
                                          micro_batch=4),
                      arbitration=arbitration)
        goodput[label] = rep.goodput_rps()
        row = rep.row()
        row.update(nodes=ARB_NODES)
        rows.append(row)
    assert (goodput["mt-arbitrated+partial"]
            > goodput["mt-independent-replan"]), (
        "cross-tenant arbitration with partial migrations must beat "
        f"independent re-planning on aggregate goodput: {goodput}")
    return rows


def run(scale_requests: int = 100_000, write: bool = True,
        budget_s: Optional[float] = SCALE_WALL_BUDGET_S) -> dict:
    """Run all sections; optionally write ``BENCH_pipeline.json``.

    ``scale_requests`` shrinks the scale section for the perf-regression
    check's reduced configuration (``scripts/check_perf.py``); the
    multitenant section always runs at full size (its simulated metrics
    are compared exactly against the committed baseline). ``budget_s``
    None disables every wall-time assert (the gate bands wall time
    itself).
    """
    result = dict(
        table1=table1_rows(),
        modes=mode_rows(),
        openloop=openloop_rows(),
        batchcurve=batchcurve_rows(),
        faultstorm=faultstorm_rows(),
        dagsweep=dagsweep_rows(),
        scale=scale_rows(scale_requests, budget_s=budget_s),
        eventspersec=eventspersec_rows(),
        multitenant=multitenant_rows(
            budget_s=MT_WALL_BUDGET_S if budget_s is not None else None),
    )
    if write:
        OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")
    return result


if __name__ == "__main__":
    out = run()
    for section, rows in out.items():
        print(f"\n# {section}")
        for row in rows:
            cfg = row.pop("config", "")
            print(",".join([f"pipeline/{cfg}"]
                           + [f"{k}={v}" for k, v in row.items()]))
    print(f"\nwrote {OUT_PATH}")
