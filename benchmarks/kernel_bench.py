"""Kernel micro-benchmarks on this host (XLA path wall-clock; the Pallas
path is TPU-target and validated via interpret mode in tests).

name, us_per_call, derived GFLOP/s.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops


def _bench(fn, *args, n=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    B, H, S, D = 1, 8, 2048, 64
    q = jax.random.normal(key, (B, H, S, D), jnp.float32)
    k = jax.random.normal(key, (B, H, S, D), jnp.float32)
    v = jax.random.normal(key, (B, H, S, D), jnp.float32)
    fn = jax.jit(lambda q, k, v: ops.attention(q, k, v, impl="xla"))
    us = _bench(fn, q, k, v)
    flops = 4.0 * B * H * S * S * D * 0.5
    rows.append(dict(config="attention-xla-2k", us_per_call=round(us, 1),
                     gflops=round(flops / us / 1e3, 2)))

    Bm, L, Hm, P, N = 1, 2048, 8, 64, 64
    x = jax.random.normal(key, (Bm, L, Hm, P), jnp.float32) * 0.3
    dt = jax.nn.softplus(jax.random.normal(key, (Bm, L, Hm))) * 0.1
    a = -jnp.exp(jax.random.normal(key, (Hm,)) * 0.3)
    bm = jax.random.normal(key, (Bm, L, 1, N)) * 0.3
    cm = jax.random.normal(key, (Bm, L, 1, N)) * 0.3
    fn = jax.jit(lambda *t: ops.ssd(*t, chunk=256, impl="xla")[0])
    us = _bench(fn, x, dt, a, bm, cm)
    rows.append(dict(config="ssd-xla-2k", us_per_call=round(us, 1),
                     gflops=round(6.0 * Bm * L * Hm * P * N / us / 1e3, 2)))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
