"""AMP4EC partitioning applied to the assigned transformer architectures.

Shows the paper's technique as a first-class framework feature on modern
LLM families: layer-wise cost analysis (Eq. 9 generalized), greedy vs
optimal boundaries, heterogeneous capability weighting, and the TPU stage
mapping (stage FLOP times + ICI boundary-transfer times per v5e chip group).

Run:  PYTHONPATH=src python examples/partition_transformer.py [--arch qwen2-7b]
"""

import argparse

from repro.configs import ARCH_IDS, get_config
from repro.core.cost_model import tpu_boundary_ms, tpu_stage_ms
from repro.core.partitioner import ModelPartitioner
from repro.models.graph import transformer_graph


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=ARCH_IDS)
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=4096)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    g = transformer_graph(cfg, batch=args.batch, seq=args.seq)
    p = ModelPartitioner(g)
    print(f"{cfg.name}: {len(g.layers)} graph layers, "
          f"{g.total_params/1e9:.2f}B params, {g.total_flops/1e12:.1f} TFLOP/fwd")

    print("\nlayer analysis (first 6 rows, paper §III-B1):")
    for row in p.analyze()[:6]:
        print(f"  {row['name']:22s} {row['kind']:16s} "
              f"params={row['params']:>12,} cost={row['cost']:.3g}")

    for method in ("greedy", "optimal"):
        plan = p.plan(args.stages, method=method)
        print(f"\n{method} {args.stages}-way: sizes={plan.sizes} "
              f"imbalance={plan.imbalance:.3f} comm={plan.comm_bytes/1e6:.1f}MB")

    # heterogeneous: two big chip groups + two half-size groups
    weights = [2.0, 2.0, 1.0, 1.0]
    plan = p.plan(args.stages, weights=weights, method="optimal")
    print(f"\nheterogeneous-weighted optimal (weights {weights}): "
          f"sizes={plan.sizes}")
    chips = [128, 128, 64, 64]
    for part, n in zip(plan.partitions, chips):
        flops = sum(l.flops for l in g.layers[part.lo:part.hi])
        print(f"  stage {part.index}: layers [{part.lo:3d},{part.hi:3d}) "
              f"on {n:3d} chips -> {tpu_stage_ms(flops, n):7.3f} ms compute, "
              f"boundary {tpu_boundary_ms(part.out_bytes):6.3f} ms ICI")


if __name__ == "__main__":
    main()
