"""Training driver: data pipeline -> AdamW -> checkpoints -> eval.

Default is a ~12M-param model for a few hundred steps (tractable on this
1-core CPU container); ``--size 100m`` selects the ~100M configuration for
real hardware. Loss drops toward the synthetic corpus' conditional entropy,
demonstrating the full substrate end-to-end.

Run:  PYTHONPATH=src python examples/train_small.py [--steps 300] [--size 12m]
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.data import DataConfig, batches_for_model
from repro.models.model import Model
from repro.optim import adamw, cosine_with_warmup
from repro.train import train
from repro.train.step import make_eval_step

SIZES = {
    # name -> (layers, d_model, heads, kv, d_ff, vocab)
    "12m": (4, 256, 4, 2, 1024, 8192),
    "100m": (12, 768, 12, 4, 3072, 32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--size", choices=SIZES, default="12m")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    L, D, H, KV, FF, V = SIZES[args.size]
    cfg = dataclasses.replace(
        get_config("yi-9b"), name=f"dense-{args.size}", num_layers=L,
        d_model=D, num_heads=H, num_kv_heads=KV, head_dim=D // H,
        d_ff=FF, vocab_size=V)
    model = Model(cfg)
    print(f"model: {cfg.name}, {model.param_count()/1e6:.1f}M params")

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch)
    opt = adamw(cosine_with_warmup(1e-3, 20, args.steps))
    params, opt_state, hist = train(
        model, opt, batches_for_model(cfg, dc), args.steps,
        log_every=max(args.steps // 10, 1),
        ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 2, 1))

    import jax
    eval_step = jax.jit(make_eval_step(model))
    batch = next(batches_for_model(cfg, dc))
    m = eval_step(params, batch)
    print(f"\nfinal eval: nll {float(m['nll']):.4f}  ppl {float(m['ppl']):.2f}")
    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"({args.steps} steps); checkpoints in {args.ckpt_dir}")
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.5, "training failed to learn"


if __name__ == "__main__":
    main()
