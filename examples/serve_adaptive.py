"""End-to-end driver: adaptive serving of a small LM with batched requests.

The paper's kind is *inference*, so the end-to-end example serves: a reduced
qwen2.5 model is briefly trained (so generations are non-degenerate), then
served through the AMP4EC scheduling stack on the heterogeneous edge cluster
with REAL greedy decoding, including the paper's two dynamic scenarios:

  phase 1: 3-node cluster, 24 batched requests
  phase 2: a new device joins  -> throughput rises
  phase 3: a device goes offline -> NSA routes around it, no failures
  phase 4: the partitioned pipeline runs CLOSED-LOOP: the
           AdaptationController re-partitions the model live when a node
           dies mid-run and again when it recovers

Run:  PYTHONPATH=src python examples/serve_adaptive.py
"""

import numpy as np

from repro.configs import get_config
from repro.core.adaptation import node_death, node_recovery
from repro.core.cluster import make_paper_cluster
from repro.core.partitioner import ModelPartitioner
from repro.core.pipeline import DistributedInference
from repro.data import DataConfig, batches_for_model
from repro.models.graph import transformer_graph
from repro.models.model import Model
from repro.optim import adamw, cosine_with_warmup
from repro.serving import Request, ServingEngine
from repro.train import train


def phase(engine, name, n_requests, start_id=0):
    reqs = [Request(start_id + i, np.arange(3, 11, dtype=np.int32) + (i % 4), 8)
            for i in range(n_requests)]
    m = engine.serve(reqs)
    print(f"  [{name}] {m['num_requests']} reqs | "
          f"avg latency {m['avg_latency_ms']:.1f} ms | "
          f"ttft {m['avg_ttft_ms']:.1f} ms | "
          f"{m['tokens_per_s']:.1f} tok/s | per-node {m['requests_per_node']}")
    return m


def main():
    cfg = get_config("qwen2.5-3b").reduced()
    model = Model(cfg)
    print(f"training reduced {cfg.name} ({model.param_count()/1e6:.1f}M params) "
          "for 60 steps so generations are non-degenerate...")
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    opt = adamw(cosine_with_warmup(3e-3, 10, 60))
    params, _, hist = train(model, opt, batches_for_model(cfg, dc), 60,
                            log_every=30, remat=False)

    cluster = make_paper_cluster()
    engine = ServingEngine(cfg, params, cluster, max_batch=4)

    print("\nphase 1: standard 3-node cluster")
    m1 = phase(engine, "3 nodes", 24)

    print("phase 2: new device joins (paper §I: 'new device added')")
    cluster.add_node("edge-3-high", "high")
    m2 = phase(engine, "4 nodes", 24, start_id=100)

    print("phase 3: device goes offline (paper §I: 'device offline')")
    cluster.remove_node("edge-2-low")
    m3 = phase(engine, "3 nodes (1 lost)", 24, start_id=200)

    assert m2["tokens_per_s"] > m1["tokens_per_s"], "join should raise throughput"
    assert all("edge-2-low" != n for n in m3["requests_per_node"]), \
        "offline node must receive no traffic"
    print("\nadaptation checks passed: join raised throughput; "
          "offline node excluded by the NSA.")
    print("cluster event log:")
    for e in cluster.events:
        print("  ", e)

    print("\nphase 4: closed-loop re-partitioning (AdaptationController)")
    # edge-scale LM graph (int8-deployed so partitions fit the 512MB nodes)
    graph = transformer_graph(get_config("mamba2-130m"), batch=1, seq=512)
    c4 = make_paper_cluster()
    pipe = DistributedInference(c4, ModelPartitioner(graph), opt_level="int8",
                                adaptive=True)
    warm = pipe.run(16, name="steady", concurrency=4)
    t0 = c4.clock.now_ms
    victim = pipe.placement[max(pipe.placement)]
    span = warm.steady_latency_ms * 48      # fault early, recover mid-run
    rep = pipe.run(48, name="fault+recover", concurrency=4,
                   scenario=[node_death(t0 + 0.1 * span, victim),
                             node_recovery(t0 + 0.4 * span, victim)])
    print(f"  steady {warm.steady_latency_ms:.1f} ms -> with fault+recovery "
          f"{rep.steady_latency_ms:.1f} ms "
          f"({pipe.controller.migrations} live migrations)")
    print("  adaptation event log:")
    for line in rep.adaptation["events"]:
        print("   ", line)
    assert pipe.controller.migrations >= 2, \
        "death and recovery must each trigger a live re-partition"


if __name__ == "__main__":
    main()
