"""Quickstart: AMP4EC on the paper's own model (MobileNetV2).

Partitions MobileNetV2 into 3 resource-aware segments (reproducing the
paper's [108, 16, 17]), deploys on the simulated heterogeneous edge cluster
(High / Medium / Low profiles), verifies the partitioned numerics against
the monolithic forward with REAL JAX compute, and prints a Table-I-style
comparison.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.core import (DistributedInference, EdgeCluster, ModelPartitioner,
                        make_paper_cluster, run_monolithic)
from repro.models.graph import mobilenetv2_graph
from repro.models.mobilenetv2 import build_mobilenetv2, run_range


def main():
    graph = mobilenetv2_graph()
    partitioner = ModelPartitioner(graph)
    print(f"MobileNetV2: {len(graph.layers)} leaf layers, "
          f"total cost {graph.total_cost/1e6:.1f}M units")
    for n in (2, 3):
        print(f"  {n}-way partition sizes: {partitioner.plan(n).sizes} "
              f"(paper: {'[116, 25]' if n == 2 else '[108, 16, 17]'})")

    # real-numerics check: partitioned == monolithic
    leaves = build_mobilenetv2()
    cluster = make_paper_cluster()
    amp = DistributedInference(
        cluster, partitioner,
        executor=lambda lo, hi, x, res: run_range(leaves, lo, hi, x, res))
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 96, 96, 3))
    assert amp.verify_numerics(x), "partitioned forward diverged!"
    print("partitioned forward == monolithic forward (real JAX compute)  OK")

    # Table-I style run
    mono_cluster = EdgeCluster()
    mono_cluster.add_node("mono", "monolithic")
    mono = run_monolithic(mono_cluster, ModelPartitioner(graph), 100)
    rep = amp.run(100, name="amp4ec")
    cached = DistributedInference(make_paper_cluster(), ModelPartitioner(graph),
                                  use_cache=True).run(100, repeat_rate=0.8,
                                                      name="amp4ec+cache")
    print(f"\n{'config':16s} {'latency(ms)':>12s} {'throughput(rps)':>16s}")
    for r in (mono, rep, cached):
        print(f"{r.name:16s} {r.steady_latency_ms:12.2f} {r.throughput_rps:16.3f}")
    print(f"\nlatency reduction (amp4ec+cache vs monolithic): "
          f"{100*(1 - cached.steady_latency_ms/mono.steady_latency_ms):.1f}% "
          f"(paper: 78.35%)")


if __name__ == "__main__":
    main()
